"""Async pipelined control plane + slack-bounded multi-step decode (§12).

Pins the three contracts of DESIGN.md §12:

* ``capacity.commit_horizon`` never busts an active envelope, never commits
  past a queued prefill, and honors the PAB-style predicted-prefill reserve;
* the pipelined engine (depth >= 2, projected-state forming) and multi-step
  decode commitment are *bit-identical* to the lock-step engine — same
  per-request SLO accounting, same step records — while dispatching less;
* snapshot() refuses (or drains) a pipeline in flight, and speculative
  dispatches that diverge from committed reality are rolled back.
"""
import math

import pytest

from repro.core import (LinearCostModel, SchedTask, TaskKind, commit_horizon,
                        make_scheduler, slack)
from repro.data.traces import make_gamma_trace, make_scenario
from repro.engine import (BlockAllocator, Engine, EngineConfig, Request,
                          SimExecutor)
from repro.engine.metrics import summarize
from repro.sim import replay

TRUE = LinearCostModel(a=0.003, b=190e-6, c=20e-9)
EST = LinearCostModel(a=0.003, b=150e-6, c=10e-9)


def _decode_task(i, *, slack_s, tpot, ctx=1000, now=0.0):
    """Decode task whose next-token slack at ``now`` is exactly slack_s."""
    # slack = arrival + ttft + tpot*j - now with j = next_output_idx
    j = 5
    arrival = now + slack_s - 0.5 - tpot * j
    return SchedTask(req_id=i, arrival=arrival, ttft_slo=0.5, tpot_slo=tpot,
                     next_output_idx=j, new_tokens=1, context=ctx,
                     kind=TaskKind.DECODE)


# ----------------------------------------------------------------------
# commit_horizon math
# ----------------------------------------------------------------------

def test_commit_horizon_never_busts_an_envelope():
    """Unit pin of the acceptance invariant: simulate the committed run
    with the same model and check every emission lands inside its envelope,
    across a seeded sweep of decode mixes."""
    import random
    rng = random.Random(7)
    for _ in range(200):
        n = rng.randint(1, 12)
        tasks = [_decode_task(i, slack_s=rng.uniform(0.005, 0.4),
                              tpot=rng.choice([0.02, 0.05, 0.15]),
                              ctx=rng.randint(50, 8000))
                 for i in range(n)]
        h = commit_horizon(tasks, 0.0, TRUE, max_horizon=32,
                           ttft_slo=0.5)
        assert 1 <= h <= 32
        ctx0 = sum(t.cost_context() for t in tasks)

        def cum(steps):
            return sum(TRUE.step_time(n, ctx0 + k * n)
                       for k in range(steps))
        # any commitment BEYOND the mandatory single step keeps every
        # emission inside its envelope (h == 1 adds nothing to lock-step:
        # one step runs regardless, late envelope or not)
        for k in range(1, h):
            for t in tasks:
                assert cum(k + 1) <= slack(t, 0.0) + k * t.tpot_slo + 1e-12, \
                    f"h={h}: token {k + 1} of task {t.req_id} busts envelope"
        # maximality: one more step would push some token past its envelope
        # (h == 1 may also mean "an envelope is already busting at step 1"
        # — the conservative don't-commit-when-late early-out)
        step1_feasible = all(cum(1) <= slack(t, 0.0) for t in tasks)
        if h < 32 and (h > 1 or step1_feasible):
            assert any(cum(h + 1) > slack(t, 0.0) + h * t.tpot_slo
                       for t in tasks), f"h={h} under-commits"


def test_commit_horizon_joint_bounds_property():
    """Hypothesis sweep of the FULL constraint product — n_shards ×
    free_pages × predicted_prefill_tokens × heterogeneous per-task
    tpot_slo × speculative (γ, acceptance, draft_frac) — asserting the
    returned H satisfies every documented constraint *independently*
    (each check reimplemented here from the docstring, not shared with
    the implementation): per-task envelopes under per-shard step pricing,
    the acceptance-blind KV page reservation, and the predicted-prefill
    TTFT reserve."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    from repro.core.cost_model import per_shard_model

    task_st = st.tuples(st.floats(0.01, 0.5), st.sampled_from(
        [0.02, 0.05, 0.15, 0.5]), st.integers(16, 4000))

    @hyp.given(st.lists(task_st, min_size=1, max_size=8),
               st.sampled_from([1, 2, 4, 8]),          # n_shards
               st.one_of(st.none(), st.integers(0, 64)),  # free_pages
               st.sampled_from([8, 16]),               # page_size
               st.sampled_from([0, 256, 1024]),        # predicted prefill
               st.integers(0, 4),                      # gamma
               st.floats(0.0, 1.0),                    # acceptance
               st.floats(0.0, 0.5))                    # draft_frac
    @hyp.settings(max_examples=120, deadline=None)
    def check(specs, n_shards, free_pages, page_size, predicted, gamma,
              acceptance, draft_frac):
        tasks = [_decode_task(i, slack_s=s, tpot=tp, ctx=c)
                 for i, (s, tp, c) in enumerate(specs)]
        h = commit_horizon(tasks, 0.0, TRUE, max_horizon=32, ttft_slo=0.5,
                           predicted_prefill_tokens=predicted,
                           free_pages=free_pages, page_size=page_size,
                           n_shards=n_shards, speculate=gamma,
                           acceptance=acceptance, draft_frac=draft_frac)
        assert 1 <= h <= 32
        model = per_shard_model(TRUE, n_shards)
        n = len(tasks)
        contexts = [t.cost_context() for t in tasks]
        ctx0 = sum(contexts)
        if gamma:
            emit = 1.0 + acceptance * gamma
            round_tokens = n * (gamma + 1) + math.ceil(n * gamma
                                                       * draft_frac)
            slots = gamma + 1
        else:
            emit, round_tokens, slots = 1.0, n, 1

        def cum(rounds):
            return sum(model.step_time(round_tokens, ctx0 + k * n * slots)
                       for k in range(rounds))
        # (1) every task's own envelope, per-shard pricing (k=0 mandatory)
        for k in range(1, h):
            for t in tasks:
                assert cum(k + 1) <= slack(t, 0.0) + k * emit * t.tpot_slo \
                    + 1e-12, f"H={h}: round {k + 1} busts {t.req_id}"
        # (2) KV page reservation, γ+1 slots/seq/round, acceptance-blind
        if h > 1 and free_pages is not None:
            need = 0
            for c in contexts:
                tail = (-c) % page_size
                grow = h * slots
                if grow > tail:
                    need += -(-(grow - tail) // page_size)
            assert need <= free_pages, f"H={h} outruns the page pool"
        # (3) predicted-prefill TTFT reserve
        if h > 1 and predicted:
            assert cum(h) + model.step_time(predicted, 0) <= 0.5 + 1e-12, \
                f"H={h} busts the predicted prefill's TTFT"

    check()


def test_commit_horizon_monotone_in_slack():
    # tpot below per-step time: each committed step *consumes* slack, so the
    # initial slack is what bounds the horizon
    tight = [_decode_task(0, slack_s=0.02, tpot=0.002)]
    loose = [_decode_task(0, slack_s=0.4, tpot=0.002)]
    h_tight = commit_horizon(tight, 0.0, TRUE, max_horizon=4096,
                             ttft_slo=0.5)
    h_loose = commit_horizon(loose, 0.0, TRUE, max_horizon=4096,
                             ttft_slo=0.5)
    assert 4096 > h_loose > h_tight >= 1


def test_commit_horizon_is_one_with_queued_prefill():
    """A queued prefill is owed chunks now — committing past it would
    recreate exactly the decode-prioritizing unfairness of paper Fig 1."""
    tasks = [_decode_task(0, slack_s=2.0, tpot=0.05),
             SchedTask(req_id=1, arrival=0.0, ttft_slo=0.5, tpot_slo=0.05,
                       next_output_idx=0, new_tokens=512, context=0,
                       kind=TaskKind.PREFILL)]
    assert commit_horizon(tasks, 0.0, TRUE, max_horizon=64,
                          ttft_slo=0.5) == 1


def test_commit_horizon_predicted_prefill_reserve():
    """PAB-style reserve: the horizon must leave room for a predicted
    prompt to land inside its TTFT SLO (never busts a queued prefill's
    TTFT: the commitment time plus its prefill time fits the SLO)."""
    tasks = [_decode_task(i, slack_s=5.0, tpot=0.5) for i in range(4)]
    free = commit_horizon(tasks, 0.0, TRUE, max_horizon=256,
                          ttft_slo=0.5)
    reserved = commit_horizon(tasks, 0.0, TRUE, max_horizon=256,
                              ttft_slo=0.5,
                              predicted_prefill_tokens=1024)
    assert reserved < free
    # invariant: commitment + predicted prefill compute <= TTFT SLO
    ctx0 = sum(t.cost_context() for t in tasks)
    cum = sum(TRUE.step_time(4, ctx0 + k * 4) for k in range(reserved))
    assert cum + TRUE.step_time(1024, 0) <= 0.5 + 1e-12


def test_commit_horizon_capped_and_degenerate():
    tasks = [_decode_task(0, slack_s=100.0, tpot=1.0)]
    assert commit_horizon(tasks, 0.0, TRUE, max_horizon=8,
                          ttft_slo=0.5) == 8
    assert commit_horizon(tasks, 0.0, TRUE, max_horizon=1,
                          ttft_slo=0.5) == 1
    assert commit_horizon([], 0.0, TRUE, max_horizon=8,
                          ttft_slo=0.5) == 1


# ----------------------------------------------------------------------
# lock-step parity: multi-step commitment and the pipelined engine
# ----------------------------------------------------------------------

def _lockstep_engine(trace, *, seed, horizon=1, depth=1, gc=0.0):
    cfg = EngineConfig(0.5, 0.05, commit_horizon=horizon,
                       pipeline_depth=depth)
    eng = Engine(make_scheduler("fairbatching",
                                LinearCostModel(EST.a, EST.b, EST.c)),
                 SimExecutor(TRUE, seed=seed, gc_pause_every=gc),
                 cfg)
    for i, tr in enumerate(sorted(trace, key=lambda t: t.arrival)):
        eng.submit(Request(i, tr.arrival, tr.prompt_len, tr.output_len,
                           0.5, 0.05))
    eng.run()
    return eng


def _per_request(done):
    return sorted((m.req_id, m.ttft, m.tpot_max, m.sched_delay, m.slo_ok)
                  for m in done)


def test_multistep_commitment_is_bit_identical_to_lockstep():
    """H-committed runs replay the exact lock-step trajectory — same step
    records, same SLO accounting — in ~H× fewer dispatches during decode
    phases (with GC pauses on, to stress the jitter/GC RNG stream too)."""
    trace = make_gamma_trace("qwentrace", rps=1.2, duration=40, seed=3)
    base = _lockstep_engine(trace, seed=7, horizon=1, gc=5.0)
    multi = _lockstep_engine(trace, seed=7, horizon=8, gc=5.0)
    assert _per_request(multi.done) == _per_request(base.done)
    assert ([(s.t_start, s.t_end, s.new_tokens, s.context)
             for s in multi.steps]
            == [(s.t_start, s.t_end, s.new_tokens, s.context)
                for s in base.steps])
    assert multi.n_dispatches < base.n_dispatches, \
        "horizon never committed: test is inert"
    # calibration saw the same per-step stream
    assert multi.sched.model == base.sched.model


def test_pipelined_replay_matches_sequential_replay():
    """Depth-2 projected-state forming with zero host overhead must be
    bit-identical to the sequential engine: the projection at t_end equals
    the committed post-step state."""
    trace = make_gamma_trace("qwentrace", rps=4.0, duration=30, seed=5)
    seq = replay(trace, scheduler="fairbatching", n_ranks=2, lb="pab",
                 admission=True, true_model=TRUE, est_model=EST, seed=9)
    pipe = replay(trace, scheduler="fairbatching", n_ranks=2, lb="pab",
                  admission=True, true_model=TRUE, est_model=EST, seed=9,
                  pipeline_depth=2)
    assert pipe.summary == seq.summary
    assert _per_request(pipe.metrics) == _per_request(seq.metrics)
    assert pipe.rank_dispatch == seq.rank_dispatch


@pytest.mark.parametrize("scenario,rps,seed,horizon", [
    ("bursty-gamma", 3.0, 17, 16),
    ("bursty-gamma", 6.0, 4, 1),
    ("multi-turn", 3.0, 8, 4),
    ("multi-turn", 1.0, 2, 16),
])
def test_async_parity_fixed_grid(scenario, rps, seed, horizon):
    """Deterministic subset of the hypothesis sweep below, so the parity
    contract is exercised even where hypothesis is unavailable."""
    trace = make_scenario(scenario, rps=rps, duration=12, seed=seed)
    kw = dict(scheduler="fairbatching", n_ranks=1, lb="roundrobin",
              true_model=TRUE, est_model=EST, seed=seed)
    seq = replay(trace, **kw)
    pipe = replay(trace, pipeline_depth=2, commit_horizon=horizon, **kw)
    assert _per_request(pipe.metrics) == _per_request(seq.metrics)
    ss, sp = dict(seq.summary), dict(pipe.summary)
    assert sp.pop("dispatches") <= ss.pop("dispatches")
    assert _eq_nan(sp, ss)


def test_async_parity_hypothesis_sweep():
    """Satellite: pipelined mode (depth 2, + multi-step commitment) emits
    identical SLO accounting to lock-step across bursty-gamma and
    multi-turn scenarios."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.sampled_from(["bursty-gamma", "multi-turn"]),
           st.sampled_from([1.0, 3.0, 6.0]),
           st.integers(0, 10_000),
           st.sampled_from([1, 4, 16]))
    @settings(max_examples=10, deadline=None)
    def check(scenario, rps, seed, horizon):
        trace = make_scenario(scenario, rps=rps, duration=12, seed=seed % 97)
        kw = dict(scheduler="fairbatching", n_ranks=1, lb="roundrobin",
                  true_model=TRUE, est_model=EST, seed=seed)
        seq = replay(trace, **kw)
        pipe = replay(trace, pipeline_depth=2, commit_horizon=horizon, **kw)
        assert _per_request(pipe.metrics) == _per_request(seq.metrics)
        ss, sp = dict(seq.summary), dict(pipe.summary)
        assert sp.pop("dispatches") <= ss.pop("dispatches")
        assert _eq_nan(sp, ss)

    check()


def _eq_nan(a: dict, b: dict) -> bool:
    if a.keys() != b.keys():
        return False
    for k in a:
        x, y = a[k], b[k]
        if isinstance(x, float) and isinstance(y, float) \
                and math.isnan(x) and math.isnan(y):
            continue
        if x != y:
            return False
    return True


def test_pipelining_hides_host_overhead():
    """With a real per-dispatch host cost, the sequential engine pays a
    bubble between steps; depth-2 forming under the running step removes it
    (shorter makespan, better tails). Multi-step commitment then removes
    dispatches themselves."""
    trace = make_gamma_trace("qwentrace", rps=3.0, duration=30, seed=11)
    kw = dict(scheduler="fairbatching", n_ranks=1, lb="roundrobin",
              true_model=TRUE, est_model=EST, seed=2, host_overhead=0.004)
    seq = replay(trace, **kw)
    pipe = replay(trace, pipeline_depth=2, **kw)
    multi = replay(trace, pipeline_depth=2, commit_horizon=16, **kw)
    assert pipe.duration < seq.duration
    assert pipe.summary["tpot_p99"] <= seq.summary["tpot_p99"]
    assert multi.summary["dispatches"] < pipe.summary["dispatches"]
    # commitment must not cost SLO attainment: that's the slack bound's job
    assert multi.summary["slo_attainment"] >= seq.summary["slo_attainment"]


# ----------------------------------------------------------------------
# real data plane: H committed decode steps == ONE device dispatch
# ----------------------------------------------------------------------

def test_real_executor_multistep_decode_parity():
    """PagedTransformerExecutor: an H-step committed decode horizon emits
    bit-identical tokens to H single-step dispatches, runs as exactly one
    jit dispatch, and rides its own compile key."""
    import dataclasses as dc

    import jax

    from repro.configs import get_reduced
    from repro.engine import PagedTransformerExecutor
    from repro.models import ModelOpts, build_model

    cfg = dc.replace(get_reduced("stablelm-3b"), window=None)
    model = build_model(cfg, ModelOpts(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))

    def run(horizon):
        execu = PagedTransformerExecutor(cfg, params, num_pages=128,
                                         page_size=16, max_pages_per_seq=8)
        eng = Engine(make_scheduler("fairbatching",
                                    LinearCostModel(1e-4, 1e-6, 1e-10)),
                     execu, EngineConfig(5.0, 5.0, commit_horizon=horizon))
        rng = jax.random.PRNGKey(3)
        for i in range(4):
            plen = 5 + 9 * i
            toks = [int(x) for x in jax.random.randint(
                jax.random.fold_in(rng, i), (plen,), 0, cfg.vocab)]
            eng.submit(Request(i, 0.0, plen, 13, 5.0, 5.0, tokens=toks))
        n = 0
        while eng.has_work and n < 300:
            eng.step()
            n += 1
        assert not eng.has_work
        return eng, execu

    base, ex1 = run(1)
    multi, ex4 = run(4)
    assert ({r: list(multi.requests[r].generated_tokens)
             for r in multi.requests}
            == {r: list(base.requests[r].generated_tokens)
                for r in base.requests})
    # same scheduler-step trajectory, fewer device dispatches
    assert len(multi.steps) == len(base.steps)
    assert multi.n_dispatches < base.n_dispatches
    # H steps => 1 dispatch: engine dispatches == executor jit launches
    assert ex4.n_dispatches == multi.n_dispatches
    assert ex1.n_dispatches == base.n_dispatches == len(base.steps)
    assert any(k[0] == "multi" and k[2] == 4 for k in ex4.compile_keys), \
        sorted(ex4.compile_keys)
    # deferral-free run must not leak pages
    assert ex4.alloc.free_blocks == ex1.alloc.free_blocks


# ----------------------------------------------------------------------
# snapshot/restore and speculative rollback
# ----------------------------------------------------------------------

def _engine_with_work(depth=2, n_req=6):
    eng = Engine(make_scheduler("fairbatching",
                                LinearCostModel(EST.a, EST.b, EST.c)),
                 SimExecutor(TRUE, seed=4),
                 EngineConfig(0.5, 0.05, pipeline_depth=depth))
    for i in range(n_req):
        eng.submit(Request(i, 0.0, 64 + 16 * i, 24, 0.5, 0.05))
    return eng


def test_snapshot_refuses_inflight_step():
    """Regression: snapshotting between begin and complete used to silently
    drop the launched batch's effects on restore."""
    eng = _engine_with_work()
    assert eng.begin_step(0.0) is not None
    with pytest.raises(RuntimeError, match="in.?flight"):
        eng.snapshot()
    eng.complete_step()
    eng.snapshot()                          # idle pipeline: fine again


def test_snapshot_drain_roundtrip_mid_pipeline():
    """snapshot(drain=True) completes the pipeline first; the restored
    engine finishes every request with consistent accounting."""
    eng = _engine_with_work(depth=2)
    for _ in range(10):
        eng.step()
    assert eng.begin_step() is not None
    assert eng.begin_step() is not None     # two dispatches in flight
    assert len(eng.inflight_q) == 2
    blob = eng.snapshot(drain=True)
    assert not eng.inflight_q               # drained, effects applied
    eng2 = _engine_with_work(depth=2)
    eng2.restore(blob)
    assert eng2.now == eng.now
    assert set(eng2.active) == set(eng.active)
    eng2.run()
    assert not eng2.has_work
    for rid in eng2.requests:
        req = eng2.requests[rid]
        if not req.active:
            assert req.prefilled == req.prompt_len

def test_projection_matches_completion():
    """The speculative view formed mid-flight must equal the committed
    state once the step lands (the depth-2 parity invariant, unit-sized)."""
    eng = _engine_with_work(depth=2)
    for _ in range(10):
        eng.step()
    inf = eng.begin_step()
    assert inf is not None
    proj, active_proj = eng._projected_requests()
    snap = {rid: (proj[rid].prefilled, proj[rid].generated)
            for rid in active_proj}
    eng.complete_step()
    real = {rid: (eng.requests[rid].prefilled, eng.requests[rid].generated)
            for rid in eng.active}
    assert snap == real
    assert sorted(active_proj) == sorted(eng.active)


def test_diverged_speculation_rolls_back():
    """A queued dispatch whose plan no longer matches committed reality is
    dropped at reconciliation, and the engine still finishes everything."""
    eng = _engine_with_work(depth=2, n_req=3)
    for _ in range(10):
        eng.step()
    assert eng.begin_step() is not None
    second = eng.begin_step()
    assert second is not None and len(eng.inflight_q) == 2
    # sabotage: force a request referenced by the queued dispatch to look
    # finished, as an executor-side surprise completion would
    rid = second.plan.items[0].req_id
    req = eng.requests[rid]
    req.max_new_tokens = max(req.generated, 1)
    eng.complete_step()                     # applies 1st, reconciles 2nd
    assert eng.rollbacks >= 1
    assert all(all(it.req_id != rid for it in inf.plan.items)
               or inf.deferred for inf in eng.inflight_q)
    while eng.inflight_q:
        eng.complete_step()
    eng.run()
    assert not eng.has_work


def test_allocator_shrink_rollback_invariants():
    """KV-side rollback: shrink() returns exactly the reserved tail pages
    and preserves the allocator conservation law."""
    alloc = BlockAllocator(16, block_size=4)
    tbl = alloc.extend(1, 10)               # 3 pages
    assert len(tbl) == 3
    free0 = alloc.free_blocks
    alloc.extend(1, 6)                      # reserve a horizon of 6 -> 4 pages
    assert alloc.free_blocks == free0 - 1
    alloc.shrink(1, 6)                      # roll the horizon back
    assert alloc.free_blocks == free0
    assert alloc.context_len(1) == 10
    assert len(alloc.tables[1]) == 3
    alloc.check_invariants()
    with pytest.raises(AssertionError):
        alloc.shrink(1, 11)                 # can't shrink past zero


# ----------------------------------------------------------------------
# metrics plumbing
# ----------------------------------------------------------------------

def test_sched_delay_and_host_breakdown_in_summary():
    trace = make_gamma_trace("qwentrace", rps=2.0, duration=20, seed=1)
    res = replay(trace, n_ranks=1, lb="roundrobin", true_model=TRUE,
                 est_model=EST, seed=0, host_overhead=0.002)
    s = res.summary
    for key in ("sched_delay_p50", "sched_delay_p99", "sched_delay_mean",
                "dispatches", "host_overhead_s", "engine_steps",
                "rollbacks"):
        assert key in s, key
    assert s["sched_delay_p50"] >= 0.0
    assert s["dispatches"] > 0
    assert abs(s["host_overhead_s"] - 0.002 * s["dispatches"]) < 1e-9
    # per-request delays survive into the metrics objects
    delays = [m.sched_delay for m in res.metrics if m.sched_delay is not None]
    assert delays and all(d >= 0 for d in delays)
    # and summarize() merges engine counters only when given
    bare = summarize(res.metrics, 1.0)
    assert "dispatches" not in bare and "sched_delay_p50" in bare
