"""End-to-end contracts of the quantized paged-KV data plane (DESIGN.md §14).

Four layers, each pinned against an oracle:

* fused vs sequential — with int8 KV the quantization error lives in the
  *shared* pages, not in the execution strategy, so both modes must still
  produce equal token streams on identical plans (the §11 parity contract
  survives quantization);
* KV parity — ``kv_parity_report`` compares a quantized executor's
  dequantized pages against an fp32 twin that ran the identical teacher-
  forced prefill: layer 0 within the exact ``row_error_bound``, deeper
  layers within a documented compounding slack;
* scheduling bit-identity — two engines differing only in ``kv_dtype``
  (equal page counts, deterministic ``ModelTimedExecutor`` clock) must
  form byte-identical plans, deferral sets, and VTC billing counters:
  token *values* drift within the §14 bound, token *counts* never;
* equal-HBM capacity — sizing both pools from ``kv_page_budget`` at the
  same byte budget, int8's extra pages must translate into equal-or-fewer
  preemptions and an equal-or-better prefix-cache hit rate.
"""
import dataclasses

import pytest

jax = pytest.importorskip("jax")

from repro.core import LinearCostModel, make_scheduler
from repro.core.cost_model import kv_bytes_per_token, kv_page_budget
from repro.core.types import BatchItem, BatchPlan, TaskKind
from repro.engine import (Engine, EngineConfig, PagedTransformerExecutor,
                          Request)
from repro.engine.numerics import (ModelTimedExecutor, assert_same_decisions,
                                   capture_schedule, kv_parity_report,
                                   vtc_counters)
from repro.engine.request import RequestState
from repro.kernels import quant as kvq

PAGE = 8
MODEL = LinearCostModel(a=1e-3, b=1e-4, c=0.0)
# Compounding envelope for layers > 0 (see test_kv_parity_prefill_oracle):
# layer l's inputs already carry the previous layers' dequantization error
# through attention + MLP, so its K/V rows drift beyond the single-row
# bound. Empirically the reduced config stays under ~10x; 64x documents
# the order of magnitude while staying far from fp32-noise false passes.
DEEP_LAYER_SLACK = 64.0


@pytest.fixture(scope="module")
def setup():
    from repro.configs import get_reduced
    from repro.models import ModelOpts, build_model
    cfg = dataclasses.replace(get_reduced("stablelm-3b"), window=None)
    model = build_model(cfg, ModelOpts(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _executor(cfg, params, *, kv_dtype="int8", mode="fused", num_pages=64,
              max_pages=16, **kw):
    return PagedTransformerExecutor(cfg, params, num_pages=num_pages,
                                    page_size=PAGE,
                                    max_pages_per_seq=max_pages,
                                    mode=mode, kv_dtype=kv_dtype, **kw)


def _requests(cfg, n_req, plen, n_new, seed=5, tenant=None):
    rng = jax.random.PRNGKey(seed)
    return [Request(i, arrival=0.0, prompt_len=plen, max_new_tokens=n_new,
                    ttft_slo=10.0, tpot_slo=10.0,
                    tenant=(tenant(i) if tenant else "default"),
                    tokens=[int(x) for x in jax.random.randint(
                        jax.random.fold_in(rng, i), (plen,), 0, cfg.vocab)])
            for i in range(n_req)]


def _drive(execs, worlds, chunk):
    """Deterministic fixed-chunk round-robin: every executor runs the SAME
    plan sequence (no scheduler feedback), mirroring the hybrid-step bench
    driver. Returns per-mode {req_id: generated_tokens}."""
    ref = worlds[next(iter(execs))]
    steps = 0
    while any(r.active for r in ref.values()):
        items = []
        for r in ref.values():
            if not r.active:
                continue
            if r.state is RequestState.DECODE:
                items.append(BatchItem(r.req_id, 1, TaskKind.DECODE))
            else:
                n = min(chunk, r.prompt_len - r.prefilled)
                items.append(BatchItem(r.req_id, n, TaskKind.PREFILL))
        if not items:
            break
        plan = BatchPlan(items, 0.0, 0.0, 0, 0)
        for m, execu in execs.items():
            requests = worlds[m]
            _, emitted = execu.execute(plan, requests, float(steps))
            assert not execu.last_deferred, "pool sized to never defer"
            for it in plan.items:
                req = requests[it.req_id]
                if it.req_id in emitted:
                    req.generated_tokens.append(emitted[it.req_id])
                req.advance(it.n_tokens, float(steps))
        steps += 1
    return {m: {rid: list(r.generated_tokens) for rid, r in worlds[m].items()}
            for m in execs}


# ---------------------------------------------------------------------------
# fused vs sequential under int8: the §11 parity contract survives
# ---------------------------------------------------------------------------


def test_int8_fused_matches_sequential_tokens(setup):
    """Quantization error is state, not noise: both modes round-trip the
    same int8 pages + scale pages, so identical plans must yield equal
    token streams — any divergence is a scatter/gather or scale-table bug,
    not 'expected quantization drift'."""
    cfg, params = setup
    execs = {m: _executor(cfg, params, kv_dtype="int8", mode=m)
             for m in ("fused", "sequential")}
    worlds = {m: {r.req_id: r for r in _requests(cfg, 4, plen=22, n_new=8)}
              for m in execs}
    tokens = _drive(execs, worlds, chunk=12)
    assert tokens["fused"] == tokens["sequential"], \
        "modes diverged on identical plans under int8 KV"
    assert all(len(t) == 8 for t in tokens["fused"].values())
    for m, execu in execs.items():
        for rid in worlds[m]:
            execu.release(rid)
        execu.alloc.check_invariants()


# ---------------------------------------------------------------------------
# KV parity oracle: dequantized pages vs the fp32 twin
# ---------------------------------------------------------------------------


def test_kv_parity_prefill_oracle(setup):
    """Teacher-forced chunked prefill on identical tokens: layer 0's K/V
    depend only on the embeddings, so its dequantized rows must sit within
    the *exact* row_error_bound; deeper layers compound through attention
    and MLP and are pinned by ``DEEP_LAYER_SLACK``."""
    cfg, params = setup
    exq = _executor(cfg, params, kv_dtype="int8")
    exr = _executor(cfg, params, kv_dtype="fp32")
    execs = {"q": exq, "ref": exr}
    # prompt crosses page boundaries and leaves a partial tail page
    worlds = {m: {r.req_id: r for r in _requests(cfg, 2, plen=37, n_new=1,
                                                 seed=7)}
              for m in execs}
    _drive(execs, worlds, chunk=16)
    for rid in worlds["q"]:
        report = kv_parity_report(exq, exr, rid)
        assert len(report) == cfg.n_layers
        lp0 = report[0]
        assert lp0.k_bound > 0 and lp0.v_bound > 0
        assert lp0.within(1.0), (
            f"layer 0 beyond the exact bound: k {lp0.k_err:.3e} vs "
            f"{lp0.k_bound:.3e}, v {lp0.v_err:.3e} vs {lp0.v_bound:.3e}")
        for lp in report[1:]:
            assert lp.within(DEEP_LAYER_SLACK), (
                f"layer {lp.layer} drifted beyond {DEEP_LAYER_SLACK}x the "
                f"row bound: k {lp.k_err:.3e}/{lp.k_bound:.3e}, "
                f"v {lp.v_err:.3e}/{lp.v_bound:.3e}")
    for m, execu in execs.items():
        for rid in worlds[m]:
            execu.release(rid)


def test_fp8_spec_gating_is_consistent():
    """`kv_quant_spec("fp8_e4m3")` and `supports_fp8()` must agree: a
    backend without float8_e4m3fn gets a clear ValueError, never a silent
    int8 fallback."""
    if kvq.supports_fp8():
        spec = kvq.kv_quant_spec("fp8_e4m3")
        assert spec is not None and spec.qmax == 448.0
    else:
        with pytest.raises(ValueError, match="fp8_e4m3"):
            kvq.kv_quant_spec("fp8_e4m3")
    with pytest.raises(ValueError):
        kvq.kv_quant_spec("int4")
    assert kvq.kv_quant_spec("fp32") is None


# ---------------------------------------------------------------------------
# scheduling bit-identity: fp32 vs int8 at equal page counts
# ---------------------------------------------------------------------------


def _sched_run(cfg, params, kv_dtype):
    execu = _executor(cfg, params, kv_dtype=kv_dtype, num_pages=48,
                      max_pages=8)
    eng = Engine(make_scheduler("fairbatching", MODEL, vtc=True,
                                calibrate=False),
                 ModelTimedExecutor(execu, MODEL),
                 EngineConfig(ttft_slo=0.5, tpot_slo=0.05))
    trace = capture_schedule(eng)
    rng = jax.random.PRNGKey(9)
    for i in range(10):
        plen = 10 + (7 * i) % 28
        eng.submit(Request(i, arrival=0.01 * i, prompt_len=plen,
                           max_new_tokens=6, ttft_slo=0.5, tpot_slo=0.05,
                           tenant="interactive" if i % 2 else "batch",
                           tokens=[int(x) for x in jax.random.randint(
                               jax.random.fold_in(rng, i), (plen,), 0,
                               cfg.vocab)]))
    eng.run(max_steps=3000)
    assert len(eng.done) == 10, "workload did not complete"
    execu.alloc.check_invariants()
    counts = {rid: r.generated for rid, r in eng.requests.items()}
    return trace, vtc_counters(eng), counts


@pytest.mark.slow
def test_scheduling_decisions_bit_identical_fp32_vs_int8(setup):
    """The §14 acceptance contract: token VALUES may drift within the
    quantization bound, but every *scheduling* decision — plan contents
    and order, deferral sets, per-tenant VTC billing — must be
    byte-identical between fp32 and int8 engines at equal page counts.
    ``ModelTimedExecutor`` supplies the deterministic clock that makes the
    two traces comparable (DESIGN.md §14)."""
    cfg, params = setup
    t32, c32, n32 = _sched_run(cfg, params, "fp32")
    t8, c8, n8 = _sched_run(cfg, params, "int8")
    assert len(t32.plans) > 10, "trace too short to be meaningful"
    assert_same_decisions(t32, t8, "fp32 vs int8")
    assert t32.fingerprint() == t8.fingerprint()
    assert c32 == c8, f"VTC counters diverged: {c32} vs {c8}"
    assert set(c32) == {"interactive", "batch"}, "both tenants billed"
    assert n32 == n8, "per-request generated counts diverged"


def _rebuild_prompt(cfg, prefixes, i):
    rng = jax.random.fold_in(jax.random.PRNGKey(21), i)
    # suffixes stay under one page so requests publish ONLY their group's
    # prefix pages — cache contention is purely between the two prefixes
    extra = 2 + (3 * i) % 6
    return prefixes[i % len(prefixes)] + [
        int(x) for x in jax.random.randint(rng, (extra,), 0, cfg.vocab)]


def _capacity_run(cfg, params, kv_dtype, hbm_bytes):
    """One end-to-end run with BOTH the KV pool and the prefix-cache
    capacity funded from the same HBM byte budget — the cache stores KV
    pages too, so quantization buys it headroom at the same rate."""
    from repro.cache import PrefixCache
    bpt = kv_bytes_per_token(cfg.n_layers, cfg.n_kv_heads, cfg.head_dim,
                             kv_dtype)
    pages = kv_page_budget(hbm_bytes, PAGE, bpt)
    execu = _executor(cfg, params, kv_dtype=kv_dtype, num_pages=pages,
                      max_pages=16)
    cache = PrefixCache(max(4, pages // 5), block_size=PAGE,
                        alloc=execu.alloc)
    execu.attach_cache(cache)
    eng = Engine(make_scheduler("fairbatching", MODEL, calibrate=False),
                 ModelTimedExecutor(execu, MODEL),
                 EngineConfig(ttft_slo=0.5, tpot_slo=0.05, preemption=True,
                              defer_age=0.005),
                 prefix_cache=cache)
    # two 24-token (3-page) prefix groups, interleaved arrivals: retaining
    # BOTH groups takes 6 cache pages — above the fp32 budget's cache,
    # within the int8 budget's
    prefixes = [[int(x) for x in
                 jax.random.randint(jax.random.PRNGKey(20 + g), (24,),
                                    0, cfg.vocab)] for g in range(2)]
    n_req = 8
    for i in range(n_req):
        prompt = _rebuild_prompt(cfg, prefixes, i)
        eng.submit(Request(i, arrival=0.002 * i, prompt_len=len(prompt),
                           max_new_tokens=16, ttft_slo=0.5, tpot_slo=0.05,
                           tokens=prompt))
    eng.run(max_steps=8000)
    assert len(eng.done) == n_req, f"{kv_dtype}: workload did not complete"
    # probe wave: one fresh request per group, pressure-free, AFTER the
    # pressure wave — its ``cached_context`` counts exactly the prompt
    # tokens served from what the cache *retained* (the raw hit-rate ratio
    # is confounded: preemption victims re-look-up prefixes they just
    # published, inflating the pressured run's hits)
    for g in range(2):
        rng = jax.random.fold_in(jax.random.PRNGKey(33), g)
        prompt = prefixes[g] + [int(x) for x in
                                jax.random.randint(rng, (4,), 0, cfg.vocab)]
        eng.submit(Request(100 + g, arrival=eng.now, prompt_len=len(prompt),
                           max_new_tokens=2, ttft_slo=0.5, tpot_slo=0.05,
                           tokens=prompt))
    eng.run(max_steps=2000)
    assert len(eng.done) == n_req + 2
    probe_cached = sum(eng.requests[100 + g].cached_context
                       for g in range(2))
    execu.alloc.check_invariants()
    return eng, cache, pages, probe_cached


@pytest.mark.slow
def test_int8_capacity_outperforms_fp32_at_equal_hbm(setup):
    """Equal HBM byte budget (via ``kv_page_budget``) for BOTH the KV pool
    and the prefix cache: int8 funds ~4x the pages, which must show up end
    to end as equal-or-fewer preemptions under pressure and equal-or-better
    prefix retention (probe-wave cached tokens — see ``_capacity_run`` for
    why the raw hit-rate ratio can't be compared) — with every request
    completing and the allocator invariants (scale pages included) intact."""
    cfg, params = setup
    bpt32 = kv_bytes_per_token(cfg.n_layers, cfg.n_kv_heads, cfg.head_dim,
                               "fp32")
    hbm = 22 * PAGE * bpt32                    # fp32 gets exactly 22 pages
    e32, cache32, p32, probe32 = _capacity_run(cfg, params, "fp32", hbm)
    e8, cache8, p8, probe8 = _capacity_run(cfg, params, "int8", hbm)
    assert p32 == 22 and p8 > p32, f"int8 must fund more pages ({p8} vs {p32})"
    # the fp32 pool must genuinely feel the pressure the int8 pool escapes
    assert e32.defer_events + e32.preemptions > 0, \
        "fp32 run felt no page pressure — capacity comparison is vacuous"
    assert e8.preemptions <= e32.preemptions, \
        f"int8 preempted more ({e8.preemptions} vs {e32.preemptions})"
    # under pool pressure the fp32 run's cache yields pages (evict_for), so
    # later same-prefix admissions miss; the int8 budget never evicts
    assert cache8.stats.hit_rate >= cache32.stats.hit_rate, (
        f"int8 hit rate {cache8.stats.hit_rate:.3f} fell below fp32 "
        f"{cache32.stats.hit_rate:.3f}")
    assert cache8.stats.hit_rate > 0.0
    # retention floor: probes must find both 3-page prefixes still cached
    assert probe8 >= probe32, (
        f"int8 retained fewer cached prefix tokens ({probe8} vs {probe32})")
    assert probe8 >= 2 * 2 * PAGE, \
        f"int8 cache lost the shared prefixes (probe served {probe8} tokens)"
