"""Flash attention (scan + custom VJP) vs dense oracle; CP merge."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (dense_attention, flash_attention,
                                    flash_attention_with_lse,
                                    merge_partial_attention)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("window", [None, 17])
@pytest.mark.parametrize("B,Tq,Tk,H,Hkv,D,block", [
    (2, 33, 65, 8, 2, 16, 16),
    (1, 7, 7, 4, 4, 8, 4),        # square causal
    (2, 1, 40, 4, 1, 32, 16),     # decode-like MQA
])
def test_flash_matches_dense(B, Tq, Tk, H, Hkv, D, block, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Tq, H, D))
    k = jax.random.normal(ks[1], (B, Tk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Tk, Hkv, D))
    qp = jnp.broadcast_to(jnp.arange(Tk - Tq, Tk), (B, Tq))
    kp = jnp.broadcast_to(jnp.arange(Tk), (B, Tk))
    o_d = dense_attention(q, k, v, qp, kp, window=window)
    o_f = flash_attention(q, k, v, qp, kp, window=window, block=block)
    assert float(jnp.abs(o_d - o_f).max()) < 1e-5


def test_flash_custom_vjp_matches_dense_grads():
    B, Tq, Tk, H, Hkv, D = 2, 16, 32, 4, 2, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Tq, H, D))
    k = jax.random.normal(ks[1], (B, Tk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Tk, Hkv, D))
    qp = jnp.broadcast_to(jnp.arange(Tk - Tq, Tk), (B, Tq))
    kp = jnp.broadcast_to(jnp.arange(Tk), (B, Tk))

    def loss(fn):
        return lambda q, k, v: (fn(q, k, v, qp, kp, window=9) ** 2).sum()
    gd = jax.grad(loss(dense_attention), argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(loss(lambda *a, **kw: flash_attention(*a, block=8, **kw)),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gd, gf):
        assert float(jnp.abs(a - b).max()) < 2e-5


def test_context_parallel_merge_exact():
    """LSE merge over disjoint KV shards == full attention (the long_500k
    flash-decoding merge)."""
    B, Tq, Tk, H, Hkv, D = 2, 4, 64, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Tq, H, D))
    k = jax.random.normal(ks[1], (B, Tk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Tk, Hkv, D))
    qp = jnp.broadcast_to(jnp.arange(Tk - Tq, Tk), (B, Tq))
    kp = jnp.broadcast_to(jnp.arange(Tk), (B, Tk))
    parts = []
    for lo, hi in ((0, 16), (16, 48), (48, 64)):
        o, l = flash_attention_with_lse(q, k[:, lo:hi], v[:, lo:hi], qp,
                                        kp[:, lo:hi], block=16)
        parts.append((o, l))
    merged = merge_partial_attention(jnp.stack([p[0] for p in parts]),
                                     jnp.stack([p[1] for p in parts]))
    full = dense_attention(q, k, v, qp, kp)
    assert float(jnp.abs(merged - full).max()) < 1e-5


def test_flash_unroll_equivalent():
    B, Tq, Tk, H, Hkv, D = 1, 8, 24, 2, 2, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Tq, H, D))
    k = jax.random.normal(ks[1], (B, Tk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Tk, Hkv, D))
    qp = jnp.broadcast_to(jnp.arange(Tk - Tq, Tk), (B, Tq))
    kp = jnp.broadcast_to(jnp.arange(Tk), (B, Tk))
    a = flash_attention(q, k, v, qp, kp, block=8, unroll=False)
    b = flash_attention(q, k, v, qp, kp, block=8, unroll=True)
    assert float(jnp.abs(a - b).max()) < 1e-6
