"""Scale-page lifecycle invariants of the quantized paged KV (DESIGN.md §14).

Every live data page owns exactly one scale page, the pairing follows the
data page through extend/fork/COW/shrink/evict/release, and both pools
conserve. Deterministic cases pin each lifecycle edge; a hypothesis sweep
interleaves the operations randomly and asserts ``check_invariants`` (the
bijection + conservation laws) after every single op — no interleaving may
orphan or alias a scale entry.
"""
import pytest

from repro.engine.kv_manager import BlockAllocator


# ---------------------------------------------------------------------------
# deterministic lifecycle edges
# ---------------------------------------------------------------------------


def test_scale_pages_allocated_and_freed_with_data():
    alloc = BlockAllocator(8, 4)
    tbl = alloc.extend(1, 10)                   # 3 data pages
    alloc.check_invariants()
    assert len(tbl) == 3
    assert all(p in alloc.scale_of for p in tbl)
    assert len(alloc.scale_table(1)) == 3
    assert len(alloc._free) == len(alloc._free_scales) == 5
    alloc.release(1)
    alloc.check_invariants()
    assert not alloc.scale_of
    assert len(alloc._free) == len(alloc._free_scales) == 8


def test_trash_page_scale_pinned_to_zero():
    """The executor's construction order (extend(-1, page_size) on a fresh
    allocator) must yield data page 0 paired with scale page 0 — pad tokens
    route both their values and their scales to id 0."""
    alloc = BlockAllocator(16, 8)
    assert alloc.extend(-1, 8) == [0]
    assert alloc.scale_of[0] == 0


def test_fork_shares_scales_via_data_page():
    """A fork adds data-page references only: the scale pool is untouched
    and the forked request sees the same scale ids through ``scale_table``."""
    alloc = BlockAllocator(8, 4)
    tbl = alloc.extend(1, 8)
    free_scales_before = list(alloc._free_scales)
    alloc.fork(2, tbl, 8)
    alloc.check_invariants()
    assert alloc._free_scales == free_scales_before
    assert alloc.scale_table(2) == alloc.scale_table(1)
    # last release frees the shared pair exactly once
    alloc.release(1)
    alloc.check_invariants()
    assert alloc.scale_table(2) == [alloc.scale_of[p] for p in tbl]
    alloc.release(2)
    alloc.check_invariants()
    assert len(alloc._free_scales) == 8


def test_cow_event_carries_fresh_scale_page():
    """COW of a shared partial tail page allocates a *fresh* scale page for
    the copy; the event carries all four ids so the executor mirrors values
    and scales in the same drain."""
    alloc = BlockAllocator(8, 4)
    tbl = alloc.extend(1, 6)                    # partial tail page
    alloc.fork(2, tbl, 6)
    old_tail = tbl[-1]
    old_scale = alloc.scale_of[old_tail]
    alloc.extend(2, 1)                          # forces the COW
    alloc.check_invariants()
    (olds, news, s_olds, s_news) = alloc.pop_cow_events_batched()
    assert olds == [old_tail] and s_olds == [old_scale]
    new_tail = alloc.tables[2][-1]
    assert news == [new_tail] and new_tail != old_tail
    assert s_news == [alloc.scale_of[new_tail]]
    assert alloc.scale_of[new_tail] != old_scale, \
        "COW copy must not alias the survivor's scale page"
    assert alloc.scale_of[old_tail] == old_scale, \
        "survivor keeps its original scale page"
    # 2-tuple compat view drains the same queue
    assert alloc.pop_cow_events() == []


def test_shrink_releases_scale_pairs():
    alloc = BlockAllocator(8, 4)
    alloc.extend(1, 16)                         # 4 pages
    alloc.shrink(1, 9)                          # back to 7 tokens → 2 pages
    alloc.check_invariants()
    assert len(alloc.tables[1]) == 2
    assert len(alloc._free) == len(alloc._free_scales) == 6


def test_evict_request_conserves_shared_scales():
    alloc = BlockAllocator(16, 4)
    tbl = alloc.extend(1, 12)
    alloc.fork(2, tbl[:2], 8)
    alloc.extend(2, 6)                          # own tail pages
    shared_scales = [alloc.scale_of[p] for p in tbl[:2]]
    alloc.evict_request(2)
    alloc.check_invariants()
    for p, s in zip(tbl[:2], shared_scales):
        assert alloc.scale_of[p] == s, "survivor's scale pairing perturbed"


# ---------------------------------------------------------------------------
# hypothesis: random op interleavings never break the bijection
# ---------------------------------------------------------------------------


OPS = ("extend", "fork", "release", "evict", "shrink", "adopt", "drain")


def _run_program(program, block_size: int, num_blocks: int) -> None:
    """Interpret an op program against a fresh allocator, asserting the
    §14 invariants after every single op. Shared by the hypothesis sweep
    and the seeded deterministic fallback below."""
    alloc = BlockAllocator(num_blocks, block_size)
    adopted: list[int] = []                      # radix-style bare references
    for op, a, b, n in program:
        if op == "extend":
            alloc.extend(a, n)                   # None (pool full) is fine
        elif op == "fork" and a in alloc.tables and b not in alloc.tables:
            tbl = alloc.tables[a]
            k = min(n, alloc.lens[a] // block_size)      # full pages only
            alloc.fork(b, tbl[:k], k * block_size)
        elif op == "release" and a in alloc.tables:
            alloc.release(a)
        elif op == "evict" and a in alloc.tables:
            alloc.evict_request(a)
        elif op == "shrink" and a in alloc.tables:
            alloc.shrink(a, min(n, alloc.lens[a]))
        elif op == "adopt":
            if b % 2 and adopted:
                alloc.release_page(adopted.pop())
            elif alloc.refcount:
                page = sorted(alloc.refcount)[a % len(alloc.refcount)]
                alloc.acquire_page(page)
                adopted.append(page)
        elif op == "drain":
            old, new, s_old, s_new = alloc.pop_cow_events_batched()
            assert len(old) == len(new) == len(s_old) == len(s_new)
            assert len(set(new)) == len(new), "COW targets must be fresh"
            for np_, sn in zip(new, s_new):
                # the event's scale id must still be the copy's pairing
                assert alloc.scale_of.get(np_) in (sn, None)
        alloc.check_invariants()                 # after EVERY op
    # wind down: every reference path returns its scale pages
    for rid in list(alloc.tables):
        alloc.release(rid)
        alloc.check_invariants()
    for page in adopted:
        alloc.release_page(page)
    alloc.check_invariants()
    assert not alloc.scale_of and not alloc.refcount
    assert len(alloc._free) == len(alloc._free_scales) == num_blocks


def test_scale_page_invariants_seeded_interleavings():
    """Deterministic seeded sweep of the same driver (runs even where
    hypothesis is not installed)."""
    import random
    for seed in range(25):
        rng = random.Random(seed)
        program = [(rng.choice(OPS), rng.randrange(6), rng.randrange(6),
                    rng.randint(1, 17)) for _ in range(rng.randint(1, 40))]
        _run_program(program, rng.randint(1, 8), rng.randint(6, 24))


def test_scale_page_invariants_random_interleavings():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @st.composite
    def programs(draw):
        n = draw(st.integers(1, 40))
        return [(draw(st.sampled_from(OPS)),
                 draw(st.integers(0, 5)),        # request slot
                 draw(st.integers(0, 5)),        # second slot / page index
                 draw(st.integers(1, 17)))       # token count
                for _ in range(n)]

    @hyp.given(programs(), st.integers(1, 8), st.integers(6, 24))
    @hyp.settings(max_examples=150, deadline=None)
    def run(program, block_size, num_blocks):
        _run_program(program, block_size, num_blocks)

    run()
