"""Real-mode engine: paged hybrid executor vs dense-cache model oracle.

The strongest integration test in the repo: run the FULL stack (FairBatching
scheduler → engine → paged KV blocks → paged-attention kernel contract) on a
tiny dense model and check the generated tokens equal greedy decoding with
the plain dense-cache model.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.core import LinearCostModel, make_scheduler
from repro.engine import (Engine, EngineConfig, PagedTransformerExecutor,
                          Request)
from repro.models import ModelOpts, build_model

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_reduced("stablelm-3b"), window=None)
    model = build_model(cfg, ModelOpts(attn_impl="dense"))
    params = model.init(KEY)
    return cfg, model, params


def greedy_oracle(model, params, prompt, n_new):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = model.prefill(params, toks, max_len=256)
    out = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([out[-1]], jnp.int32), cache)
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def test_paged_executor_matches_dense_model(setup):
    cfg, model, params = setup
    execu = PagedTransformerExecutor(cfg, params, num_pages=64,
                                     page_size=16, max_pages_per_seq=8)
    sched = make_scheduler("fairbatching",
                           LinearCostModel(a=1e-4, b=1e-6, c=1e-10))
    eng = Engine(sched, execu, EngineConfig(ttft_slo=5.0, tpot_slo=5.0))
    rng = jax.random.PRNGKey(3)
    prompts = [
        [int(x) for x in jax.random.randint(jax.random.fold_in(rng, i),
                                            (12 + 7 * i,), 0, cfg.vocab)]
        for i in range(3)
    ]
    n_new = 6
    for i, prm in enumerate(prompts):
        r = Request(i, arrival=0.001 * i, prompt_len=len(prm),
                    max_new_tokens=n_new, ttft_slo=5.0, tpot_slo=5.0,
                    tokens=prm)
        eng.submit(r)
    eng.run(max_steps=500)
    for i, prm in enumerate(prompts):
        got = eng.requests[i].generated_tokens
        expect = greedy_oracle(model, params, prm, n_new)
        assert got == expect, f"req {i}: {got} != {expect}"


def test_block_allocator_reuse(setup):
    cfg, model, params = setup
    execu = PagedTransformerExecutor(cfg, params, num_pages=16,
                                     page_size=16, max_pages_per_seq=8)
    sched = make_scheduler("fairbatching",
                           LinearCostModel(a=1e-4, b=1e-6, c=1e-10))
    eng = Engine(sched, execu, EngineConfig(ttft_slo=5.0, tpot_slo=5.0))
    # sequential waves exercise free-list reuse
    for wave in range(3):
        prm = [1, 2, 3, 4, 5, 6, 7, 8]
        r = Request(wave, arrival=float(wave), prompt_len=len(prm),
                    max_new_tokens=4, ttft_slo=5.0, tpot_slo=5.0, tokens=prm)
        eng.submit(r)
    eng.run(max_steps=500)
    # all pages back on the free list except the reserved trash page
    assert execu.alloc.free_blocks == execu.alloc.num_blocks - 1
    outs = [eng.requests[w].generated_tokens for w in range(3)]
    assert outs[0] == outs[1] == outs[2], "page reuse corrupted state"
