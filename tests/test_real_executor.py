"""Real-mode engine: paged hybrid executor vs dense-cache model oracle.

The strongest integration test in the repo: run the FULL stack (FairBatching
scheduler → engine → paged KV blocks → paged-attention kernel contract) on a
tiny dense model and check the generated tokens equal greedy decoding with
the plain dense-cache model.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.cache import PrefixCache
from repro.configs import get_reduced
from repro.core import LinearCostModel, make_scheduler
from repro.engine import (Engine, EngineConfig, PagedTransformerExecutor,
                          Request)
from repro.models import ModelOpts, build_model

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_reduced("stablelm-3b"), window=None)
    model = build_model(cfg, ModelOpts(attn_impl="dense"))
    params = model.init(KEY)
    return cfg, model, params


def greedy_oracle(model, params, prompt, n_new):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = model.prefill(params, toks, max_len=256)
    out = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([out[-1]], jnp.int32), cache)
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def test_paged_executor_matches_dense_model(setup):
    cfg, model, params = setup
    execu = PagedTransformerExecutor(cfg, params, num_pages=64,
                                     page_size=16, max_pages_per_seq=8)
    sched = make_scheduler("fairbatching",
                           LinearCostModel(a=1e-4, b=1e-6, c=1e-10))
    eng = Engine(sched, execu, EngineConfig(ttft_slo=5.0, tpot_slo=5.0))
    rng = jax.random.PRNGKey(3)
    prompts = [
        [int(x) for x in jax.random.randint(jax.random.fold_in(rng, i),
                                            (12 + 7 * i,), 0, cfg.vocab)]
        for i in range(3)
    ]
    n_new = 6
    for i, prm in enumerate(prompts):
        r = Request(i, arrival=0.001 * i, prompt_len=len(prm),
                    max_new_tokens=n_new, ttft_slo=5.0, tpot_slo=5.0,
                    tokens=prm)
        eng.submit(r)
    eng.run(max_steps=500)
    for i, prm in enumerate(prompts):
        got = eng.requests[i].generated_tokens
        expect = greedy_oracle(model, params, prm, n_new)
        assert got == expect, f"req {i}: {got} != {expect}"


def test_block_allocator_reuse(setup):
    cfg, model, params = setup
    execu = PagedTransformerExecutor(cfg, params, num_pages=16,
                                     page_size=16, max_pages_per_seq=8)
    sched = make_scheduler("fairbatching",
                           LinearCostModel(a=1e-4, b=1e-6, c=1e-10))
    eng = Engine(sched, execu, EngineConfig(ttft_slo=5.0, tpot_slo=5.0))
    # sequential waves exercise free-list reuse
    for wave in range(3):
        prm = [1, 2, 3, 4, 5, 6, 7, 8]
        r = Request(wave, arrival=float(wave), prompt_len=len(prm),
                    max_new_tokens=4, ttft_slo=5.0, tpot_slo=5.0, tokens=prm)
        eng.submit(r)
    eng.run(max_steps=500)
    # all pages back on the free list except the reserved trash page
    assert execu.alloc.free_blocks == execu.alloc.num_blocks - 1
    outs = [eng.requests[w].generated_tokens for w in range(3)]
    assert outs[0] == outs[1] == outs[2], "page reuse corrupted state"


def _cached_engine(cfg, params, page_size=16):
    execu = PagedTransformerExecutor(cfg, params, num_pages=64,
                                     page_size=page_size, max_pages_per_seq=8)
    cache = PrefixCache(32, block_size=page_size, alloc=execu.alloc)
    execu.attach_cache(cache)
    sched = make_scheduler("fairbatching",
                           LinearCostModel(a=1e-4, b=1e-6, c=1e-10))
    eng = Engine(sched, execu, EngineConfig(ttft_slo=5.0, tpot_slo=5.0),
                 prefix_cache=cache)
    return eng, execu, cache


def test_prefix_reuse_matches_no_reuse_path(setup):
    """Acceptance (DESIGN.md §10): with the prefix cache enabled, requests
    that hit shared pages generate exactly the tokens of the cold path —
    reused KV is numerically the KV the request would have recomputed."""
    cfg, model, params = setup
    eng, execu, cache = _cached_engine(cfg, params)
    rng = jax.random.PRNGKey(5)
    shared = [int(x) for x in jax.random.randint(rng, (40,), 0, cfg.vocab)]
    prompts = [shared + [1, 2, 3], shared + [4, 5, 6, 7], shared + [1, 2, 3]]
    n_new = 6
    for i, prm in enumerate(prompts):
        # spaced arrivals: req 0 publishes its prefix before 1 and 2 look up
        eng.submit(Request(i, arrival=0.5 * i, prompt_len=len(prm),
                           max_new_tokens=n_new, ttft_slo=5.0, tpot_slo=5.0,
                           tokens=prm))
    eng.run(max_steps=500)
    assert cache.stats.hit_requests >= 2, cache.stats_dict()
    for i, prm in enumerate(prompts):
        got = eng.requests[i].generated_tokens
        expect = greedy_oracle(model, params, prm, n_new)
        assert got == expect, f"req {i}: {got} != {expect}"
    # full-reuse sanity: identical prompts produced identical outputs
    assert (eng.requests[0].generated_tokens
            == eng.requests[2].generated_tokens)


def test_prefix_reuse_logits_match_cold_prefill(setup):
    """Stronger than token equality: the first-token logits computed on top
    of cache-shared pages equal a cold full prefill within fp tolerance."""
    cfg, model, params = setup
    page = 16
    prm = [int(x) for x in jax.random.randint(jax.random.PRNGKey(9), (37,),
                                              0, cfg.vocab)]
    # cold path: one request, full prefill, capture its first-token logits
    # via the dense-model oracle's prefill
    logits_cold, _ = model.prefill(params, jnp.asarray(prm, jnp.int32)[None],
                                   max_len=64)
    # warm path: request 0 populates the cache, request 1 forks its pages
    # and prefills only the uncached tail
    eng, execu, cache = _cached_engine(cfg, params, page_size=page)
    eng.submit(Request(0, arrival=0.0, prompt_len=len(prm), max_new_tokens=1,
                       ttft_slo=5.0, tpot_slo=5.0, tokens=list(prm)))
    eng.run(max_steps=50)
    cached = cache.begin_request(1, list(prm), eng.now)
    assert cached == 32, "expected a 2-page hit"
    tail = prm[cached:]
    n_tok = 16
    toks = jnp.asarray(tail + [0] * (n_tok - len(tail)), jnp.int32)
    execu._extend(1, len(tail))
    tbl = execu._table(1)
    execu.k_pages, execu.v_pages, scales, logits_warm = execu._chunk_fn(
        execu.k_pages, execu.v_pages, execu._scales_in(), toks,
        jnp.int32(cached), tbl, execu._stable(1), jnp.int32(len(tail)),
        n_tok=n_tok)
    execu._set_scales(scales)
    assert jnp.allclose(logits_warm, logits_cold[0], atol=1e-4, rtol=1e-4), \
        float(jnp.max(jnp.abs(logits_warm - logits_cold[0])))
    cache.end_request(1)
