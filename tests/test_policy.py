"""Scheduler stack (DESIGN.md §13): stage composition is behavior-preserving
and the VTC admission stage delivers per-tenant fairness.

The refactor contract: every preconfigured stack (fairbatching and its
ablations, sarathi, vllm-vanilla) with FCFS admission produces exactly the
plans of the pre-stack monolithic schedulers — pinned here against the raw
formation/capacity primitives, which ARE the old code paths. On top, VTC
admission must (a) be invisible with a single tenant and (b) protect
interactive tenants from a flooding tenant (the acceptance bound of the
multi-tenant-adversarial scenario).
"""
import dataclasses
import math

import numpy as np

from repro.core import (FCFSAdmission, FairBatchingScheduler, FormationConfig,
                        LinearCostModel, SarathiScheduler, SchedTask,
                        SchedulerStack, TaskKind, VLLMVanillaScheduler,
                        VTCAdmission, form_batch, form_prefill_first,
                        form_stall_free, make_scheduler)
from repro.data.traces import make_scenario
from repro.sim import replay

MODEL = LinearCostModel(a=0.002, b=1.9e-4, c=2e-8)


def dec(i, j=10, ctx=500, tenant="default", tpot=0.05):
    return SchedTask(i, arrival=-1.0, ttft_slo=0.5, tpot_slo=tpot,
                     next_output_idx=j, new_tokens=1, context=ctx,
                     kind=TaskKind.DECODE, tenant=tenant)


def pre(i, n=1000, arrival=0.0, tenant="default"):
    return SchedTask(i, arrival=arrival, ttft_slo=0.5, tpot_slo=0.05,
                     next_output_idx=0, new_tokens=n, context=0,
                     kind=TaskKind.PREFILL, prompt_len=n, tenant=tenant)


def _mixed_tasks(seed=0, n=12):
    rng = np.random.default_rng(seed)
    tasks = []
    for i in range(n):
        if rng.random() < 0.5:
            tasks.append(dec(i, j=int(rng.integers(1, 40)),
                             ctx=int(rng.integers(64, 4096))))
        else:
            tasks.append(pre(i, n=int(rng.integers(16, 3000)),
                             arrival=float(rng.uniform(-0.2, 0.3))))
    return tasks


def _plans_equal(a, b):
    return (a.items == b.items
            and a.predicted_time == b.predicted_time
            and a.time_budget == b.time_budget
            and a.token_budget_used == b.token_budget_used
            and a.token_budget_total == b.token_budget_total)


# ---------------------------------------------------------------------------
# stack == monolith (bit-identical plans through the raw primitives)
# ---------------------------------------------------------------------------


def test_fairbatching_stack_matches_algorithm1_directly():
    """FB-vanilla stack (cold start, n_obs=0) == form_batch with the
    cold-start-scaled safety — exactly the monolithic scheduler's body."""
    for seed in range(5):
        tasks = _mixed_tasks(seed)
        stack = FairBatchingScheduler(MODEL, budget_mode="time")
        cfg = FormationConfig()
        ref = form_batch(tasks, 1.0, MODEL,
                         dataclasses.replace(cfg, safety=cfg.safety * 0.7))
        assert _plans_equal(stack.schedule(1.0, tasks), ref)
        # calibrate=False: no cold start, plain formation config
        warm = FairBatchingScheduler(MODEL, budget_mode="time",
                                     calibrate=False)
        assert _plans_equal(warm.schedule(1.0, tasks),
                            form_batch(tasks, 1.0, MODEL, cfg))


def test_fb_token_budget_stack_matches_reference():
    from repro.core import capacity
    for seed in range(5):
        tasks = _mixed_tasks(seed + 10)
        stack = FairBatchingScheduler(MODEL, budget_mode="token",
                                      calibrate=False)
        cfg = FormationConfig()
        t_budget = capacity.init_time_budget(tasks, 1.0, cfg.max_time_budget)
        tok = MODEL.tokens_within(t_budget) if math.isfinite(t_budget) \
            else cfg.max_token_budget
        ref_cfg = dataclasses.replace(
            cfg, max_token_budget=max(1, min(tok, cfg.max_token_budget)))
        ref_model = LinearCostModel(a=MODEL.a, b=MODEL.b, c=0.0)
        assert _plans_equal(stack.schedule(1.0, tasks),
                            form_batch(tasks, 1.0, ref_model, ref_cfg))


def test_fb_fixed_stack_matches_reference():
    for seed in range(5):
        tasks = _mixed_tasks(seed + 20)
        stack = FairBatchingScheduler(MODEL, budget_mode="fixed",
                                      fixed_token_budget=512,
                                      calibrate=False)
        cfg = dataclasses.replace(FormationConfig(), max_token_budget=512,
                                  max_time_budget=MODEL.step_time(512, 0))
        assert _plans_equal(stack.schedule(1.0, tasks),
                            form_batch(tasks, 1.0, MODEL, cfg))


def test_baseline_stacks_match_formation_primitives():
    for seed in range(5):
        tasks = _mixed_tasks(seed + 30)
        sar = SarathiScheduler(MODEL, token_budget=256)
        assert _plans_equal(sar.schedule(1.0, tasks),
                            form_stall_free(tasks, 1.0, MODEL, 256))
        van = VLLMVanillaScheduler(MODEL, max_num_batched_tokens=8192)
        assert _plans_equal(van.schedule(1.0, tasks),
                            form_prefill_first(tasks, 1.0, MODEL, 8192))


def test_custom_stack_composition():
    """Stages compose freely: a Sarathi formation under an FB capacity
    stage is a legal (if exotic) stack and still satisfies the protocol."""
    from repro.core import AdaptiveTimeCapacity, StallFreeFormation
    stack = SchedulerStack("hybrid", MODEL, admission=FCFSAdmission(),
                           capacity_policy=AdaptiveTimeCapacity(),
                           formation=StallFreeFormation(128))
    plan = stack.schedule(0.0, [dec(1), pre(2, 500)])
    assert plan.items
    stack.observe(plan.total_new_tokens, 500, 0.05)


# ---------------------------------------------------------------------------
# VTC admission stage
# ---------------------------------------------------------------------------


def test_vtc_single_tenant_is_fcfs():
    """With one tenant the VTC stage must be a pass-through: identical
    plans to the FCFS stack, step after step (the bit-identity clause)."""
    fcfs = make_scheduler("fairbatching", MODEL)
    vtc = make_scheduler("fairbatching", MODEL, vtc=True)
    for seed in range(4):
        tasks = _mixed_tasks(seed + 40)
        assert _plans_equal(fcfs.schedule(1.0, tasks),
                            vtc.schedule(1.0, tasks))


def test_vtc_holds_overdrawn_tenant_prefills():
    adm = VTCAdmission(burst_tokens=100)
    flood_p = pre(1, n=5000, tenant="flood")
    user_p = pre(2, n=200, tenant="user")
    flood_d = dec(3, tenant="flood")
    # flood has consumed far beyond its window, user nothing
    adm.counters = {"flood": 10_000.0, "user": 0.0}
    out = adm.filter(0.0, [flood_p, user_p, flood_d])
    assert user_p in out, "behind tenant's prefill must pass"
    assert flood_p not in out, "overdrawn tenant's prefill must be held"
    assert flood_d in out, "decodes always pass (KV is resident)"
    # starvation override: a data-plane-deferred task is always eligible
    starving = dataclasses.replace(flood_p, deferred_age=1.0)
    assert starving in adm.filter(0.0, [starving, user_p])
    # debt is floor-relative
    assert adm.debt() == {"flood": 10_000.0, "user": 0.0}


def test_vtc_counters_charge_weighted_service():
    adm = VTCAdmission(weights={"heavy": 2.0}, input_weight=1.0,
                       output_weight=2.0)
    tasks = [pre(1, n=100, tenant="light"), pre(2, n=100, tenant="heavy"),
             dec(3, tenant="light")]
    stack = SchedulerStack("s", MODEL, admission=adm)
    plan = stack.schedule(0.0, tasks)
    granted = {it.req_id: it.n_tokens for it in plan.items}
    assert granted.get(1) == 100 and granted.get(2) == 100
    # same service, but the weight-2 tenant is charged half
    assert adm.counters["light"] == 100.0 + 2.0 * granted.get(3, 0)
    assert adm.counters["heavy"] == 50.0


def test_vtc_refund_reverses_unexecuted_charges():
    """A grant the data plane deferred (or a rolled-back speculative plan)
    must not bill its tenant: refund reverses the on_schedule charge, so a
    tenant starved of KV pages is never pushed into overdraft by retries."""
    adm = VTCAdmission()
    stack = SchedulerStack("s", MODEL, admission=adm)
    tasks = [pre(1, n=300, tenant="a"), dec(2, tenant="b")]
    plan = stack.schedule(0.0, tasks)
    charged = dict(adm.counters)
    assert charged["a"] > 0
    # the executor could not place req 1: engine refunds its grant
    stack.refund(plan, {1})
    assert adm.counters["a"] == 0.0
    assert adm.counters["b"] == charged["b"]
    # retry re-charges; counters end exactly as if it ran once
    stack.schedule(0.0, tasks)
    assert adm.counters["a"] == charged["a"]


def test_vtc_counter_lift_on_reappearance():
    adm = VTCAdmission()
    adm.counters = {"a": 1000.0}
    adm.filter(0.0, [pre(1, tenant="a"), pre(2, tenant="b")])
    # b may not bank credit from its idle past: lifted to the known floor
    assert adm.counters["b"] == 1000.0


def test_vtc_lift_applies_to_returning_idle_tenant():
    """The no-gaming rule covers *returning* tenants too: a stale low
    counter from an idle gap must not buy absolute priority on return —
    it is lifted to the floor of the continuously-active tenants."""
    adm = VTCAdmission()
    adm.counters = {"c": 100.0, "d": 50_000.0}
    adm._last_present = {"d"}                 # d active, c idle until now
    out = adm.filter(0.0, [pre(1, tenant="c"), pre(2, tenant="d"),
                           dec(3, tenant="d")])
    assert adm.counters["c"] == 50_000.0, "idle gap banked credit"
    # with equal counters, both tenants' prefills are within the window
    assert {t.req_id for t in out} == {1, 2, 3}
    # a tenant that stays present keeps its earned deficit (no lift)
    adm.counters["c"] = 40_000.0
    adm.filter(1.0, [pre(1, tenant="c"), pre(2, tenant="d")])
    assert adm.counters["c"] == 40_000.0


def test_vtc_horizon_topup_charges_committed_tokens():
    """A committed H-step decode horizon serves H tokens per item but the
    plan carries 1-token grants; charge_extra_decode bills the rest (and
    reverses it on rollback with negative steps)."""
    adm = VTCAdmission(output_weight=2.0)
    stack = SchedulerStack("s", MODEL, admission=adm)
    tasks = [dec(1, tenant="a"), dec(2, tenant="b")]
    plan = stack.schedule(0.0, tasks)
    base = dict(adm.counters)
    stack.charge_extra_decode(plan, {1, 2}, 7)
    assert adm.counters["a"] == base["a"] + 2.0 * 7
    stack.charge_extra_decode(plan, {1, 2}, -7)
    assert adm.counters == base


# ---------------------------------------------------------------------------
# acceptance: multi-tenant-adversarial scenario (DESIGN.md §13)
# ---------------------------------------------------------------------------


def _interactive_p99_ttft(metrics):
    ttfts = [m.ttft for m in metrics
             if m.tenant != "flood" and m.ttft is not None]
    return float(np.percentile(ttfts, 99))


def test_vtc_protects_interactive_tenants_from_flood():
    """The acceptance bound: on multi-tenant-adversarial, VTC admission
    keeps the interactive tenants' p99 TTFT within 1.5x of their
    isolated-run baseline while FCFS degrades it >= 3x."""
    kw = dict(rps=1.0, duration=40.0, seed=3)
    trace = make_scenario("multi-tenant-adversarial", **kw)
    iso_trace = [t for t in trace if t.tenant != "flood"]
    assert {t.tenant for t in trace} > {t.tenant for t in iso_trace}

    # cap the largest step (the compiled-shape bound every real deployment
    # has): without it a single uncapped multi-thousand-token flood chunk
    # dominates interactive TTFT no matter who is admitted
    fc = FormationConfig(max_time_budget=0.1)

    def run(tr, **extra):
        return replay(tr, scheduler="fairbatching", n_ranks=1, lb="pab",
                      seed=3, sched_kwargs={"formation": fc, **extra})

    iso = _interactive_p99_ttft(run(iso_trace).metrics)
    fcfs = _interactive_p99_ttft(run(trace).metrics)
    vtc = _interactive_p99_ttft(run(trace, vtc=True).metrics)
    assert fcfs >= 3.0 * iso, \
        f"flood should swamp FCFS: fcfs={fcfs:.3f} iso={iso:.3f}"
    assert vtc <= 1.5 * iso, \
        f"VTC failed to protect: vtc={vtc:.3f} iso={iso:.3f}"


def test_vtc_commit_horizon_bills_exact_service():
    """Regression: a committed H-step decode horizon must bill each tenant
    exactly H output tokens — not H (top-up) + H-1 (billed horizon probes)
    as the pre-``probe()`` code did. The committed run's counters must
    equal the lock-step run's."""
    from repro.engine import Engine, EngineConfig, Request, SimExecutor

    def run(commit_horizon):
        sched = make_scheduler("fairbatching",
                               LinearCostModel(a=0.003, b=150e-6, c=10e-9),
                               vtc=True, calibrate=False)
        eng = Engine(sched, SimExecutor(
            LinearCostModel(a=0.003, b=190e-6, c=20e-9), seed=7),
            EngineConfig(0.5, 0.05, commit_horizon=commit_horizon))
        for i, tenant in enumerate(("a", "b")):
            eng.submit(Request(i, 0.0, 64, 12, 0.5, 0.05, tenant=tenant))
        eng.run()
        assert len(eng.done) == 2
        return eng.sched.admission.counters

    lockstep = run(commit_horizon=1)
    committed = run(commit_horizon=8)
    assert committed == lockstep, (committed, lockstep)
    # sanity: billed the prefill + every decode grant (the first of the 12
    # output tokens is emitted by the prefill itself, so 11 decode grants)
    assert lockstep["a"] == 64 * 1.0 + 11 * 2.0


def test_per_tenant_metrics_and_debt_reporting():
    trace = make_scenario("multi-tenant-adversarial", rps=1.0,
                          duration=10.0, seed=1)
    res = replay(trace, scheduler="fairbatching", n_ranks=1, lb="pab",
                 seed=1, sched_kwargs={"vtc": True})
    s = res.summary
    assert "per_tenant" in s and "flood" in s["per_tenant"]
    flood = s["per_tenant"]["flood"]
    assert {"ttft_p99", "tpot_p99", "slo_attainment"} <= set(flood)
    # the engine exposes the admission stage's fairness debt for LB ticks
    eng = res.cluster.engines[0]
    debt = eng.tenant_debt()
    assert debt and min(debt.values()) == 0.0
