"""Doc-suite integrity: DESIGN.md section references, README scheduler zoo."""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

from check_design_refs import design_sections, find_references  # noqa: E402


def test_design_and_readme_exist():
    assert (REPO / "DESIGN.md").is_file()
    assert (REPO / "README.md").is_file()
    assert (REPO / "benchmarks" / "README.md").is_file()


def test_every_design_ref_resolves():
    sections = design_sections(REPO / "DESIGN.md")
    refs = find_references(REPO)
    assert refs, "reference scanner found nothing — scanner broken?"
    dangling = [(f, ln, n) for f, ln, n in refs if n not in sections]
    assert not dangling, f"dangling DESIGN.md references: {dangling}"


def test_readme_documents_every_scheduler_name():
    """The scheduler-zoo table must cover every make_scheduler name."""
    from repro.core.schedulers import make_scheduler  # noqa: F401
    readme = (REPO / "README.md").read_text()
    for name in ("vllm-vanilla", "sarathi", "fairbatching",
                 "fb-token-budget", "fb-fix-batch"):
        assert f"`{name}`" in readme, f"README missing scheduler {name}"
