"""Algorithm 1 invariants (paper §3.3) — property-based."""
import math

import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import (FormationConfig, LinearCostModel, SchedTask,
                        TaskKind, classify, form_batch, init_time_budget,
                        slack)

MODEL = LinearCostModel(a=0.002, b=1.9e-4, c=2e-8)


def task_strategy(req_id):
    return st.builds(
        SchedTask,
        req_id=st.just(req_id),
        arrival=st.floats(-20.0, 0.0),
        ttft_slo=st.just(0.5),
        tpot_slo=st.sampled_from([0.05, 0.1]),
        next_output_idx=st.integers(0, 400),
        new_tokens=st.integers(1, 4096),
        context=st.integers(0, 100_000),
        kind=st.sampled_from([TaskKind.PREFILL, TaskKind.DECODE]),
    )


def fix(tasks):
    """Make task fields self-consistent."""
    out = []
    for t in tasks:
        if t.is_decode:
            t.new_tokens = 1
            t.next_output_idx = max(1, t.next_output_idx)
        else:
            t.next_output_idx = 0
        out.append(t)
    return out


tasklists = st.lists(
    st.integers(0, 10**6), min_size=1, max_size=20, unique=True).flatmap(
        lambda ids: st.tuples(*[task_strategy(i) for i in ids]))


@given(tasklists)
@settings(max_examples=200, deadline=None)
def test_urgent_decodes_always_included(tasks):
    """Paper §3.3: urgent decode tasks are never dropped (the Sarathi
    graceful-degradation guarantee)."""
    tasks = fix(list(tasks))
    now = 0.0
    cfg = FormationConfig()
    plan = form_batch(tasks, now, MODEL, cfg)
    budget = init_time_budget(tasks, now, cfg.max_time_budget)
    min_tpot = min(t.tpot_slo for t in tasks)
    in_batch = {it.req_id for it in plan.items}
    for t in tasks:
        if t.is_decode and slack(t, now) < budget + min_tpot:
            assert t.req_id in in_batch, "urgent decode dropped"


@given(tasklists)
@settings(max_examples=200, deadline=None)
def test_no_overgrant_and_token_budget(tasks):
    tasks = fix(list(tasks))
    plan = form_batch(tasks, 0.0, MODEL, FormationConfig(max_token_budget=2048))
    by_id = {t.req_id: t for t in tasks}
    granted = {}
    for it in plan.items:
        assert it.req_id not in granted, "duplicate grant"
        granted[it.req_id] = it.n_tokens
        assert 1 <= it.n_tokens <= by_id[it.req_id].new_tokens
    # token budget holds except for force-admitted urgent decodes
    n_granted = sum(granted.values())
    n_urgent = sum(1 for t in tasks if t.is_decode)
    assert n_granted <= 2048 + n_urgent


@given(tasklists)
@settings(max_examples=200, deadline=None)
def test_time_budget_respected_modulo_urgent(tasks):
    """Predicted step time ≤ safety-adjusted budget unless urgent decodes
    alone exceed it (graceful Sarathi fallback)."""
    tasks = fix(list(tasks))
    now = 0.0
    cfg = FormationConfig(max_time_budget=10.0)
    plan = form_batch(tasks, now, MODEL, cfg)
    budget = min(init_time_budget(tasks, now, cfg.max_time_budget), 10.0)
    min_tpot = min(t.tpot_slo for t in tasks)
    urgent = [t for t in tasks
              if t.is_decode and slack(t, now) < budget + min_tpot]
    urgent_cost = MODEL.step_time(
        sum(t.new_tokens for t in urgent),
        sum(t.cost_context() for t in urgent)) if urgent else 0.0
    assert plan.predicted_time <= max(budget * cfg.safety, urgent_cost) + 1e-6


def test_three_group_priority_order():
    """Prefill outranks non-urgent decode; urgent decode outranks both."""
    now = 0.0
    urgent = SchedTask(1, arrival=-10, ttft_slo=0.5, tpot_slo=0.05,
                       next_output_idx=190, new_tokens=1, context=500,
                       kind=TaskKind.DECODE)   # ddl −10+0.5+9.5=0 → slack 0
    lazy = SchedTask(2, arrival=-10, ttft_slo=0.5, tpot_slo=0.05,
                     next_output_idx=250, new_tokens=1, context=500,
                     kind=TaskKind.DECODE)     # slack = 3.0
    pre = SchedTask(3, arrival=-0.1, ttft_slo=0.5, tpot_slo=0.05,
                    next_output_idx=0, new_tokens=400, context=0,
                    kind=TaskKind.PREFILL)
    budget = init_time_budget([urgent, lazy, pre], now, math.inf)
    ud, p, nd = classify([urgent, lazy, pre], now, budget, 0.05)
    assert [t.req_id for t in ud] == [1]
    assert [t.req_id for t in p] == [3]
    assert [t.req_id for t in nd] == [2]
    # tight budget: lazy decode deferred, prefill chunked in
    small = LinearCostModel(a=0.001, b=1e-4, c=0.0)
    plan = form_batch([urgent, lazy, pre], now, small,
                      FormationConfig(max_token_budget=4096))
    ids = [it.req_id for it in plan.items]
    assert 1 in ids and 3 in ids
    grant3 = plan.tokens_for(3)
    assert grant3 > 0, "prefill got nothing despite spare budget"


def test_prefill_chunked_to_fill_budget():
    pre = SchedTask(1, arrival=0.0, ttft_slo=0.5, tpot_slo=0.05,
                    next_output_idx=0, new_tokens=100_000, context=0,
                    kind=TaskKind.PREFILL)
    dec = SchedTask(2, arrival=-5.0, ttft_slo=0.5, tpot_slo=0.05,
                    next_output_idx=95, new_tokens=1, context=400,
                    kind=TaskKind.DECODE)  # slack 0.25
    m = LinearCostModel(a=0.001, b=1e-4, c=0.0)
    plan = form_batch([pre, dec], 0.0, m, FormationConfig(max_token_budget=8192))
    g = plan.tokens_for(1)
    assert 0 < g < 100_000
    assert plan.predicted_time <= 0.25 + 1e-9


def test_empty_tasks():
    plan = form_batch([], 0.0, MODEL, FormationConfig())
    assert plan.items == [] and plan.predicted_time == 0.0
