"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step, shape + finiteness asserts, prefill↔decode consistency, MoE paths."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_archs, get_reduced
from repro.configs.base import MoEConfig
from repro.models import ModelOpts, build_model
from repro.models.moe import init_moe_params, moe_capacity, moe_dense_exact

KEY = jax.random.PRNGKey(0)


def _prefill_inputs(cfg, B, S):
    if cfg.is_encoder_decoder:
        return {"enc_embeds": jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1,
                "dec_tokens": jnp.zeros((B, 4), jnp.int32)}
    if cfg.embeds_input:
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1}
    return jax.random.randint(KEY, (B, S), 0, cfg.vocab)


def _train_batch(cfg, B, S):
    if cfg.is_encoder_decoder:
        return {"enc_embeds": jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1,
                "dec_tokens": jnp.zeros((B, S), jnp.int32)}
    if cfg.embeds_input:
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1,
                "labels": jnp.zeros((B, S), jnp.int32)}
    return {"tokens": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_forward_decode_train(arch):
    cfg = get_reduced(arch)
    m = build_model(cfg)
    p = m.init(KEY)
    B, S = 2, 24
    logits, cache = m.prefill(p, _prefill_inputs(cfg, B, S), max_len=48)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN in prefill logits"
    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = m.decode_step(p, toks, cache)
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), "NaN in decode logits"
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
    loss = m.train_loss(p, _train_batch(cfg, B, S))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), "NaN train loss"


@pytest.mark.parametrize("arch", all_archs())
def test_prefill_decode_consistency(arch):
    """decode(prefill(x[:n])) logits == prefill(x[:n+1]) logits.

    MoE archs use the exact dispatch here: the capacity path may *drop*
    tokens (production semantics, tested separately), which legitimately
    breaks bit-level prefill/decode equivalence."""
    cfg = get_reduced(arch)
    m = build_model(cfg, ModelOpts(moe_impl="exact"))
    p = m.init(KEY)
    B, S = 2, 10
    if cfg.is_encoder_decoder:
        enc = jax.random.normal(KEY, (B, 12, cfg.d_model)) * 0.1
        d0 = jnp.array([[3], [5]], jnp.int32)
        lg1, c = m.prefill(p, {"enc_embeds": enc, "dec_tokens": d0}, max_len=16)
        t1 = jnp.argmax(lg1, -1).astype(jnp.int32)
        lg2, _ = m.decode_step(p, t1, c)
        lg3, _ = m.prefill(p, {"enc_embeds": enc,
                               "dec_tokens": jnp.concatenate([d0, t1[:, None]], 1)},
                           max_len=16)
    elif cfg.embeds_input:
        emb = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1
        lg1, c = m.prefill(p, {"embeds": emb}, max_len=16)
        t1 = jnp.argmax(lg1, -1).astype(jnp.int32)
        lg2, _ = m.decode_step(p, t1, c)
        # embed the sampled token manually to extend the prompt
        nxt = m.cfg and p["embed"][t1][:, None]
        lg3, _ = m.prefill(p, {"embeds": jnp.concatenate([emb, nxt], 1)},
                           max_len=16)
    else:
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        lg1, c = m.prefill(p, toks, max_len=16)
        t1 = jnp.argmax(lg1, -1).astype(jnp.int32)
        lg2, _ = m.decode_step(p, t1, c)
        lg3, _ = m.prefill(p, jnp.concatenate([toks, t1[:, None]], 1),
                           max_len=16)
    assert float(jnp.abs(lg2 - lg3).max()) < 5e-4


def test_swa_ring_cache_matches_full_window():
    """SWA archs: decoding past the window keeps exactly the window."""
    cfg = get_reduced("h2o-danube-1.8b")   # window 16
    m = build_model(cfg)
    p = m.init(KEY)
    toks = jax.random.randint(KEY, (1, 20), 0, cfg.vocab)  # longer than W
    lg, cache = m.prefill(p, toks, max_len=32)
    # positions stored must be the LAST 16
    kvp = cache["kv"]["kv_pos"]
    stored = sorted(int(x) for x in kvp[0])
    assert stored == list(range(4, 20))


def test_moe_capacity_matches_exact_generously():
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                    capacity_factor=8.0, router_chunk=64)
    p = init_moe_params(jax.random.PRNGKey(1), 48, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (100, 48))
    a = moe_dense_exact(x, p, cfg)
    b = moe_capacity(x, p, cfg)
    assert float(jnp.abs(a - b).max()) < 1e-4


def test_moe_capacity_drop_is_bounded():
    """Tight capacity drops tokens but output stays finite and close in
    aggregate (production dropping semantics)."""
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                    capacity_factor=1.0, router_chunk=256)
    p = init_moe_params(jax.random.PRNGKey(1), 48, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (256, 48))
    y = moe_capacity(x, p, cfg)
    assert bool(jnp.isfinite(y).all())


def test_mamba_step_equals_seq():
    from repro.models.mamba2 import (init_mamba_cache, init_mamba_params,
                                     mamba_seq, mamba_step)
    cfg = get_reduced("mamba2-1.3b")
    p = init_mamba_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 33, cfg.d_model)) * 0.5
    y_full, c_full = mamba_seq(p, x, cfg)
    cache = init_mamba_cache(cfg, 2)
    ys = []
    for t in range(x.shape[1]):
        yt, cache = mamba_step(p, x[:, t:t + 1], cfg, cache)
        ys.append(yt)
    assert float(jnp.abs(jnp.concatenate(ys, 1) - y_full).max()) < 1e-4
    assert float(jnp.abs(cache["ssm"] - c_full["ssm"]).max()) < 1e-6
