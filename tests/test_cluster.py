"""Cluster layer: PAB-LB, failure re-route, stragglers, elasticity."""
from repro.cluster import Cluster, ClusterConfig, PABLB, RequestCountLB
from repro.data.traces import make_trace


def bursty_trace(rps=5.0, duration=60, seed=3):
    return make_trace("qwentrace", rps=rps, duration=duration, seed=seed)


def test_pab_lb_beats_count_lb():
    """Paper §5.5: PAB-aware load balancing > request-count balancing.

    Run near cluster saturation (~0.8 × 4 ranks × ~4 rps/rank): below that
    every balancer attains everything and the comparison is vacuous."""
    trace = bursty_trace(rps=12.0)
    res = {}
    for lb_cls in (RequestCountLB, PABLB):
        cfg = ClusterConfig(n_ranks=4, scheduler="fairbatching",
                            admission=(lb_cls is PABLB))
        cl = Cluster(cfg, lb_cls(4))
        cl.run(trace)
        res[lb_cls.name] = cl.summary()
    assert res["pab-lb"]["effective_rps"] > res["vllm-lb"]["effective_rps"]


def test_failure_reroutes_all_requests():
    trace = bursty_trace(rps=3.0)
    cfg = ClusterConfig(n_ranks=4, scheduler="fairbatching", admission=True)
    cl = Cluster(cfg, PABLB(4))
    cl.schedule_failure(20.0, 1)
    done = cl.run(trace)
    # every request is accounted for exactly once (finished or rejected)
    assert len(done) == len(trace)
    assert 1 not in cl.engines


def test_elastic_rejoin_restores_capacity():
    trace = bursty_trace(rps=4.0, duration=80)
    base = ClusterConfig(n_ranks=4, scheduler="fairbatching", admission=True)
    cl_fail = Cluster(base, PABLB(4))
    cl_fail.schedule_failure(20.0, 0)
    cl_fail.run(trace)
    cl_rejoin = Cluster(base, PABLB(4))
    cl_rejoin.schedule_failure(20.0, 0)
    cl_rejoin.schedule_join(30.0, 0)
    cl_rejoin.run(trace)
    assert (cl_rejoin.summary()["slo_attainment"]
            >= cl_fail.summary()["slo_attainment"])


def test_pab_lb_starves_straggler():
    """A 3× slower rank's calibration inflates → PAB shrinks → less load
    (DESIGN.md §7 straggler mitigation)."""
    trace = bursty_trace(rps=4.0)
    cfg = ClusterConfig(n_ranks=4, scheduler="fairbatching", admission=False,
                        straggler_ranks={0: 3.0})
    cl = Cluster(cfg, PABLB(4))
    cl.run(trace)
    loads = {r: len([1 for rid, rk in cl._rank_of.items() if rk == r])
             for r in range(4)}
    healthy_avg = sum(loads[r] for r in (1, 2, 3)) / 3
    assert loads[0] < 0.7 * healthy_avg, f"straggler not starved: {loads}"
