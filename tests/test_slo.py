"""Envelope-SLO tracking (paper §3.1): correctness + monotonicity property."""
import math

import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, strategies as st

from repro.core import (SchedTask, TaskKind, attainment, request_deadline,
                        slack, token_deadline)


def mk(arrival=0.0, ttft=0.5, tpot=0.05, j=0, kind=TaskKind.DECODE, ctx=100):
    return SchedTask(req_id=1, arrival=arrival, ttft_slo=ttft, tpot_slo=tpot,
                     next_output_idx=j, new_tokens=1, context=ctx, kind=kind)


def test_token_deadline_formula():
    assert token_deadline(10.0, 0.5, 0.05, 0) == 10.5
    assert token_deadline(10.0, 0.5, 0.05, 4) == 10.5 + 0.2


def test_prefill_deadline_is_ttft():
    t = mk(arrival=3.0, j=0, kind=TaskKind.PREFILL)
    assert request_deadline(t) == 3.5
    assert abs(slack(t, now=3.2) - 0.3) < 1e-12


@given(j1=st.integers(0, 500), j2=st.integers(0, 500),
       tpot=st.floats(0.001, 0.5), ttft=st.floats(0.01, 5.0))
def test_envelope_monotone_in_token_index(j1, j2, tpot, ttft):
    """Later tokens never have earlier deadlines (the monotonicity that
    makes the envelope fair, unlike TBT — paper §2.4)."""
    if j1 > j2:
        j1, j2 = j2, j1
    assert token_deadline(0.0, ttft, tpot, j1) <= token_deadline(0.0, ttft, tpot, j2)


@given(shift=st.floats(0.0, 1.0))
def test_earlier_generation_never_hurts(shift):
    """Shifting every output earlier keeps/improves attainment (paper's
    argument for envelope over TBT)."""
    base = [0.4, 0.5, 0.6, 0.7]
    ok_late = attainment(base, 0.0, 0.5, 0.12)
    ok_early = attainment([t - shift * 0.3 for t in base], 0.0, 0.5, 0.12)
    assert (ok_early[0] >= ok_late[0]) and (ok_early[1] >= ok_late[1])


def test_attainment_max_tpot_definition():
    # token 1 late relative to token 0 → worst-case TPOT violated even if
    # later tokens catch up on average
    times = [0.1, 0.3, 0.32, 0.34]
    ttft_ok, tpot_ok = attainment(times, 0.0, 0.5, 0.05)
    assert ttft_ok and not tpot_ok
    ttft_ok, tpot_ok = attainment([0.1, 0.14, 0.18, 0.22], 0.0, 0.5, 0.05)
    assert ttft_ok and tpot_ok
