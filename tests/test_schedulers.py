"""Baseline schedulers reproduce their papers' behaviours (§2.3)."""
from repro.core import (LinearCostModel, SarathiScheduler, SchedTask,
                        TaskKind, VLLMVanillaScheduler, make_scheduler)

MODEL = LinearCostModel(a=0.002, b=1.9e-4, c=2e-8)


def dec(i, j=10, ctx=500):
    return SchedTask(i, arrival=-1.0, ttft_slo=0.5, tpot_slo=0.05,
                     next_output_idx=j, new_tokens=1, context=ctx,
                     kind=TaskKind.DECODE)


def pre(i, n=1000, arrival=0.0):
    return SchedTask(i, arrival=arrival, ttft_slo=0.5, tpot_slo=0.05,
                     next_output_idx=0, new_tokens=n, context=0,
                     kind=TaskKind.PREFILL, prompt_len=n)


def test_sarathi_stall_free():
    """Every active decode is in every batch; leftover budget → chunked
    prefill FCFS."""
    s = SarathiScheduler(MODEL, token_budget=256)
    tasks = [dec(i) for i in range(10)] + [pre(100, 5000, arrival=0.0),
                                           pre(101, 5000, arrival=0.1)]
    plan = s.schedule(1.0, tasks)
    ids = {it.req_id for it in plan.items}
    assert all(i in ids for i in range(10)), "decode stalled"
    assert plan.tokens_for(100) == 256 - 10      # FCFS chunk fills leftover
    assert plan.tokens_for(101) == 0
    assert plan.total_new_tokens == 256


def test_vanilla_prefill_first_starves_decode():
    v = VLLMVanillaScheduler(MODEL, max_num_batched_tokens=8192)
    tasks = [dec(i) for i in range(4)] + [pre(100, 3000)]
    plan = v.schedule(1.0, tasks)
    assert plan.tokens_for(100) == 3000
    assert not plan.decode_items, "vanilla should run the prefill batch alone"
    # without waiting prefills it runs a pure decode batch
    plan2 = v.schedule(1.0, [dec(i) for i in range(4)])
    assert len(plan2.decode_items) == 4


def test_factory_names():
    for name in ("vllm-vanilla", "sarathi", "fairbatching",
                 "fb-token-budget", "fb-fix-batch"):
        s = make_scheduler(name, LinearCostModel(0.002, 1e-4, 1e-9))
        assert s.schedule(0.0, [dec(1)]).items


def test_fb_variants_differ_under_long_context():
    """FB-TB ignores context in budgeting; FB-vanilla charges it (paper
    Fig-7 step 4)."""
    long_ctx = [dec(i, j=5, ctx=80_000) for i in range(8)] + [pre(99, 2000)]
    tb = make_scheduler("fb-token-budget", LinearCostModel(0.002, 1e-4, 2e-8))
    tv = make_scheduler("fairbatching", LinearCostModel(0.002, 1e-4, 2e-8))
    p_tb = tb.schedule(0.0, long_ctx)
    p_tv = tv.schedule(0.0, long_ctx)
    # token-budget variant over-packs tokens: it ignores the context cost
    # that the time-budget variant charges (paper's ±5.2% failure mode)
    assert p_tb.total_new_tokens > p_tv.total_new_tokens
